"""Streaming exchange engine vs per-step-jit dispatch.

The continuous-time hot path is the *time* loop: T exchange rounds per
emulation, every round re-dispatched from Python in the eager path.  This
benchmark drives the same fused route-merge-pack datapath both ways —

  * ``per_step_loop`` — one jit'd exchange round dispatched T times
    (route_step / route_step_hierarchical), the pre-streaming behaviour;
  * ``scan_stream``   — the streaming engine: all T rounds in one compiled
    program (``fused_exchange_stream`` for the star; ``lax.scan`` over the
    stacked two-layer round for the hierarchical topology), routing tables
    staged once.

— at the paper's deployed ``FULL_BACKPLANE`` (12 chips, one star) and the
§V ``PROJECTED_120CHIP`` (10 backplanes × 12 chips, two-layer) topologies,
and reports µs/step and routed events/s.  Outputs are asserted identical
before timing.

Writes ``stream_*`` keys into ``BENCH_interconnect.json`` (merged with the
single-round keys from ``interconnect_throughput.py``); see that module's
docstring for the key glossary.
"""

import json
import os
import time

import jax
import jax.numpy as jnp

from repro.core import (FULL_BACKPLANE, PROJECTED_120CHIP, full_route_enables,
                        identity_router, make_frame, route_step,
                        route_step_hierarchical)
from repro.kernels.spike_router.ops import fused_exchange_stream

BENCH_JSON = os.environ.get("BENCH_INTERCONNECT_JSON",
                            "BENCH_interconnect.json")
N_STEPS = 64


def _merge_bench_json(updates, path=BENCH_JSON):
    """Merge ``stream_*`` keys into the shared benchmark JSON."""
    payload = {}
    if os.path.exists(path):
        with open(path) as f:
            payload = json.load(f)
    payload.update({k: round(v, 3) for k, v in updates.items()})
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    return path


def _frames_for(n_nodes: int, cap_in: int, n_steps: int, key):
    labels = jax.random.randint(key, (n_steps, n_nodes, cap_in), 0, 2**15)
    valid = jax.random.uniform(jax.random.fold_in(key, 1),
                               (n_steps, n_nodes, cap_in)) < 0.5
    frames, _ = make_frame(labels, None, valid, cap_in)
    return frames


def _time_loop(step_fn, frames, n_steps, trials=3):
    """T per-step dispatches, each jit'd but driven from Python.

    Min over ``trials`` — dispatch timing is sensitive to transient host
    load, and the minimum is the contention-free estimate.
    """
    out = [step_fn(jax.tree.map(lambda x: x[t], frames))
           for t in range(n_steps)]                       # compile + warm
    jax.block_until_ready(out[-1])
    best = float("inf")
    for _ in range(trials):
        t0 = time.perf_counter()
        for t in range(n_steps):
            out_t = step_fn(jax.tree.map(lambda x: x[t], frames))
        jax.block_until_ready(out_t)
        best = min(best, time.perf_counter() - t0)
    return best, out


def _time_scan(stream_fn, frames, trials=3):
    out = stream_fn(frames)                               # compile
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(trials):
        t0 = time.perf_counter()
        out = stream_fn(frames)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best, out


def _check_equal(loop_out, scan_out, n_steps):
    scan_l, scan_v, scan_d = scan_out
    for t in range(n_steps):
        fr_t, d_t = loop_out[t]
        assert jnp.array_equal(jnp.where(fr_t.valid, fr_t.labels, 0),
                               jnp.where(scan_v[t], scan_l[t], 0))
        assert jnp.array_equal(fr_t.valid, scan_v[t])
        assert jnp.array_equal(d_t, scan_d[t])


def run(verbose: bool = True, n_steps: int = N_STEPS):
    key = jax.random.key(0)
    results = {}
    rows = []

    cases = (
        ("FULL_BACKPLANE", FULL_BACKPLANE, 64, 256),
        ("PROJECTED_120CHIP", PROJECTED_120CHIP, 32, 128),
    )
    for name, topo, cap_in, cap in cases:
        n = topo.n_chips
        state = identity_router(n)
        frames = _frames_for(n, cap_in, n_steps, jax.random.fold_in(key, n))
        n_events = int(frames.valid.sum())

        if topo.second_layer:
            n_pods = topo.n_backplanes
            intra = full_route_enables(topo.chips_per_backplane)
            inter = full_route_enables(n_pods)

            step_fn = jax.jit(lambda f: route_step_hierarchical(
                state, f, cap, n_pods=n_pods, intra_enables=intra,
                inter_enables=inter))

            def _scan(fr):
                def body(_, fr_t):
                    from repro.core.events import EventFrame
                    out, dropped = route_step_hierarchical(
                        state, EventFrame(*fr_t), cap, n_pods=n_pods,
                        intra_enables=intra, inter_enables=inter)
                    return None, (out.labels, out.valid, dropped)
                _, outs = jax.lax.scan(body, None, tuple(fr))
                return outs

            stream_fn = jax.jit(_scan)
        else:
            step_fn = jax.jit(lambda f: route_step(state, f, cap))
            stream_fn = jax.jit(lambda fr: fused_exchange_stream(
                fr.labels, fr.valid, state.fwd_tables, state.rev_tables,
                state.route_enables, capacity=cap))

        t_loop, loop_out = _time_loop(step_fn, frames, n_steps)
        t_scan, scan_out = _time_scan(stream_fn, frames)
        _check_equal(loop_out, scan_out, n_steps)

        speedup = t_loop / t_scan
        loop_us = t_loop / n_steps * 1e6
        scan_us = t_scan / n_steps * 1e6
        ev_s = n_events / t_scan
        tag = f"[{name},T={n_steps}]"
        results[f"stream_loop_us_per_step{tag}"] = loop_us
        results[f"stream_scan_us_per_step{tag}"] = scan_us
        results[f"stream_speedup{tag}"] = speedup
        results[f"stream_scan_events_per_s{tag}"] = ev_s
        rows.append((name, n_steps, loop_us, scan_us, speedup, ev_s))
        if verbose:
            print(f"exchange_stream[{name} loop],{loop_us:.0f},us/step")
            print(f"exchange_stream[{name} scan],{scan_us:.0f},us/step "
                  f"({ev_s/1e6:.1f}M events/s)")
            print(f"exchange_stream[{name} speedup],{scan_us:.0f},"
                  f"{speedup:.2f}x vs per-step dispatch")

    path = _merge_bench_json(results)
    if verbose:
        print(f"exchange_stream[json],0,wrote {path}")
    return rows


if __name__ == "__main__":
    run()
