"""Event-routing datapath throughput on the 4-chip prototype topology.

Times the full route_step (fwd LUT → Aggregator all-to-all → reverse LUT →
capacity pack) and the fused Pallas spike_router kernel (interpret mode on
CPU — wall time is *not* TPU-representative; the derived column carries the
per-event work, which is).
"""

import time

import jax
import jax.numpy as jnp

from repro.core import identity_router, make_frame, route_step
from repro.core.routing import build_fwd_table
from repro.kernels.spike_router.ops import route_and_pack


def _time(fn, *args, reps=20):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run(verbose: bool = True):
    rows = []
    key = jax.random.key(0)
    for n_events, cap in ((64, 256), (256, 1024), (1024, 4096)):
        state = identity_router(4)
        labels = jax.random.randint(key, (4, n_events), 0, 2**15)
        valid = jax.random.uniform(jax.random.fold_in(key, 1),
                                   (4, n_events)) < 0.5
        frames, _ = make_frame(labels, jnp.zeros_like(labels), valid, n_events)
        step = jax.jit(lambda f: route_step(state, f, cap))
        us = _time(step, frames)
        per_event = us / (4 * n_events)
        rows.append(("route_step", n_events, us, per_event))
        if verbose:
            print(f"interconnect[route_step n={n_events}],{us:.0f},"
                  f"{per_event*1000:.1f}ns/event")

    ids = jnp.arange(4096)
    lut = build_fwd_table(ids, ids)
    for n_events in (256, 1024):
        labels = jax.random.randint(key, (4, n_events), 0, 4096)
        valid = jax.random.uniform(key, (4, n_events)) < 0.5
        fn = jax.jit(lambda l, v: route_and_pack(l, v, lut, capacity=512,
                                                 interpret=True))
        us = _time(fn, labels, valid, reps=5)
        rows.append(("spike_router_kernel", n_events, us, us / (4 * n_events)))
        if verbose:
            print(f"interconnect[pallas_router n={n_events}],{us:.0f},"
                  "interpret-mode (CPU)")
    return rows


if __name__ == "__main__":
    run()
