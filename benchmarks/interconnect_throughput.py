"""Event-routing datapath throughput on the 4-chip prototype topology.

Headline before/after for the fused exchange datapath: the seed's argsort
compaction + broadcast materialization (``route_step_baseline``) against the
cumsum/scatter route-merge-pack path (``route_step``, fused).  Also times the
unfused cumsum composition (isolating the compaction-scheme win from the
kernel fusion) and the Pallas kernel in interpret mode (semantics check —
wall time is *not* TPU-representative).

Writes ``BENCH_interconnect.json`` next to the CSV lines so the perf
trajectory is tracked across PRs.  ``benchmarks/exchange_stream.py`` merges
its ``stream_*`` keys into the same file; the full key glossary lives in the
top-level README.md.
"""

import json
import os
import time

import jax
import jax.numpy as jnp

from repro.core import identity_router, make_frame, route_step, \
    route_step_baseline
from repro.core.routing import build_fwd_table
from repro.kernels.spike_router.ops import route_and_pack

BENCH_JSON = os.environ.get("BENCH_INTERCONNECT_JSON",
                            "BENCH_interconnect.json")


def _time(fn, *args, reps=20):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def write_bench_json(rows, path=BENCH_JSON):
    """Persist machine-readable ``{name: us_per_call}`` for CI tracking."""
    payload = {name: round(us, 3) for name, _, us, _ in rows}
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    return path


def run(verbose: bool = True):
    rows = []
    key = jax.random.key(0)
    for n_events, cap in ((64, 256), (256, 1024), (1024, 4096)):
        state = identity_router(4)
        labels = jax.random.randint(key, (4, n_events), 0, 2**15)
        valid = jax.random.uniform(jax.random.fold_in(key, 1),
                                   (4, n_events)) < 0.5
        frames, _ = make_frame(labels, jnp.zeros_like(labels), valid, n_events)

        variants = (
            ("argsort_baseline",
             jax.jit(lambda f: route_step_baseline(state, f, cap))),
            ("cumsum_unfused",
             jax.jit(lambda f: route_step(state, f, cap, use_fused=False))),
            ("fused",
             jax.jit(lambda f: route_step(state, f, cap, use_fused=True))),
        )
        timings = {}
        for variant, step in variants:
            us = _time(step, frames)
            timings[variant] = us
            per_event = us / (4 * n_events)
            rows.append((f"route_step_{variant}[n={n_events}]",
                         n_events, us, per_event))
            if verbose:
                print(f"interconnect[route_step_{variant} n={n_events}],"
                      f"{us:.0f},{per_event*1000:.1f}ns/event")
        if verbose:
            speedup = timings["argsort_baseline"] / timings["fused"]
            print(f"interconnect[speedup n={n_events}],"
                  f"{timings['fused']:.0f},{speedup:.2f}x vs argsort")

    ids = jnp.arange(4096)
    lut = build_fwd_table(ids, ids)
    for n_events in (256, 1024):
        labels = jax.random.randint(key, (4, n_events), 0, 4096)
        valid = jax.random.uniform(key, (4, n_events)) < 0.5
        fn = jax.jit(lambda l, v: route_and_pack(l, v, lut, capacity=512,
                                                 interpret=True))
        us = _time(fn, labels, valid, reps=5)
        rows.append((f"spike_router_kernel_interpret[n={n_events}]",
                     n_events, us, us / (4 * n_events)))
        if verbose:
            print(f"interconnect[pallas_router n={n_events}],{us:.0f},"
                  "interpret-mode (CPU)")

    path = write_bench_json(rows)
    if verbose:
        print(f"interconnect[json],0,wrote {path}")
    return rows


if __name__ == "__main__":
    run()
