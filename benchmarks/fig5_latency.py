"""Fig 5A reproduction: spike latency distributions vs regular rate,
3:1 fan-in, 2^15 spikes — Node-FPGA level and BSS-2 chip level.

Paper claims validated here: chip-to-chip median within 0.9–1.3 µs for all
rates; discretization at 8 ns; worst-regime jitter ≈ 15 % of median; on-chip
jitter compensation visible below ~100 MHz aggregate rates.
"""

import time

import jax
import numpy as np

from repro.core import latency_statistics, simulate_fan_in

RATES_HZ = [1e6, 5e6, 10e6, 25e6, 50e6, 70e6, 80e6, 83.3e6]
N_SPIKES = 2 ** 15


def run(verbose: bool = True):
    key = jax.random.key(0)
    rows = []
    for level in ("fpga", "chip"):
        for rate in RATES_HZ:
            t0 = time.perf_counter()
            lats = simulate_fan_in(rate, N_SPIKES,
                                   jax.random.fold_in(key, int(rate)),
                                   fan_in=3, level=level)
            stats = {k: float(v) for k, v in latency_statistics(lats).items()}
            us = (time.perf_counter() - t0) * 1e6
            rows.append((level, rate, stats, us))
            if verbose:
                print(f"fig5_latency[{level}@{rate/1e6:.1f}MHz],{us:.0f},"
                      f"median={stats['median_ns']:.0f}ns "
                      f"p99={stats['p99_ns']:.0f}ns "
                      f"jitter={stats['jitter_frac']*100:.1f}%")
    chip = [r for r in rows if r[0] == "chip"]
    meds = [r[2]["median_ns"] for r in chip]
    assert all(850 <= m <= 1300 for m in meds), "outside the paper's band!"
    if verbose:
        print(f"fig5_latency[summary],0,chip-to-chip median "
              f"{min(meds):.0f}–{max(meds):.0f} ns across rates "
              f"(paper: 0.9–1.3 µs) — REPRODUCED")
    return rows


if __name__ == "__main__":
    run()
