"""The paper's technique at LM scale: event-frame MoE dispatch.

Compares the sort/prefix-sum (event-frame) dispatch against the GShard-style
one-hot einsum on dispatch-tensor *memory* (the reason the event-frame path
is the only viable one for 160-expert DeepSeek-V2) and times the small-scale
forward on CPU.  Also sweeps capacity factor vs dropped-token fraction —
the congestion/loss trade the paper measures on the spike fabric (Fig 5).
"""

import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_config
from repro.models import moe as moelib
from repro.models.model import init_params
import dataclasses


def run(verbose: bool = True):
    rows = []
    # Dispatch-tensor memory: event-frame vs one-hot, DeepSeek-V2 full scale.
    ds = get_config("deepseek-v2-236b")
    tokens = 4096                       # per-device tokens at train_4k
    cap = moelib.expert_capacity(tokens, ds)
    # Expert buffers [E, C, D] are common to both schemes; the routing
    # metadata differs: a dense one-hot dispatch tensor [N, E, C] vs the
    # event list [N·top_k × (label, slot)] — spikes vs dense state.
    onehot_bytes = tokens * ds.n_experts * cap * 2          # [N, E, C] bf16
    event_bytes = tokens * ds.top_k * (4 + 4)               # int32 label+slot
    rows.append(("dispatch_memory", onehot_bytes, event_bytes))
    if verbose:
        print(f"moe_dispatch[memory],0,one-hot dispatch tensor="
              f"{onehot_bytes/1e6:.0f}MB event-frame metadata="
              f"{event_bytes/1e6:.2f}MB "
              f"({onehot_bytes/event_bytes:.0f}x smaller)")

    # Capacity factor vs drop fraction (congestion-loss curve).
    cfg = smoke_config(get_config("deepseek-v2-236b"))
    key = jax.random.key(0)
    for cf in (1.0, 1.25, 2.0, 8.0):
        c = dataclasses.replace(cfg, capacity_factor=cf)
        params = init_params(key, c)
        moe_params = jax.tree.map(lambda p: p, params["moe"],
                                  is_leaf=lambda x: hasattr(x, "value"))
        # extract one layer's moe params (leading layer axis)
        import repro.models.layers as L
        one = jax.tree.map(lambda p: L.Param(p.value[0], p.axes[1:]),
                           params["moe"], is_leaf=L.is_param)["moe"]
        x = jax.random.normal(key, (4, 64, c.d_model), jnp.float32)
        fwd = jax.jit(lambda pp, xx: moelib.moe_forward(pp, xx, c))
        y, metrics = fwd(one, x)                 # compile + warm
        jax.block_until_ready(y)
        # Steady-state per-call time: the first call above includes
        # trace+compile and must never be the reported number.
        n_calls = 10
        t0 = time.perf_counter()
        for _ in range(n_calls):
            y, metrics = fwd(one, x)
        jax.block_until_ready(y)
        us = (time.perf_counter() - t0) / n_calls * 1e6
        dropped = float(metrics["dropped_frac"])
        rows.append(("capacity_sweep", cf, dropped, us))
        if verbose:
            print(f"moe_dispatch[cf={cf}],{us:.0f},dropped={dropped*100:.1f}%")
    return rows


if __name__ == "__main__":
    run()
