"""Render §Roofline markdown tables from results/dryrun*.json into
EXPERIMENTS.md (replaces the content between the §3 and §4 headers —
re-runnable)."""

import json
import os
import re

ROOT = os.path.join(os.path.dirname(__file__), "..")

HEADER_NOTE = """
(Each cell: three terms in seconds from the per-device partitioned program /
single-chip peaks; dominant term; MODEL_FLOPS = 6·N_active·D for train,
2·N_active·D forward-only; useful = MODEL_FLOPS / global HLO FLOPs;
roofline = ideal-model-math-time / dominant-term time.)
"""


def _cell_mesh(cell: str) -> str:
    return cell.split("|")[2]


def table(results: dict, mesh: str) -> str:
    hdr = ("| arch | shape | compute | memory | collective | dominant | "
           "temps/dev | useful | roofline |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for cell in sorted(results):
        rec = results[cell]
        if _cell_mesh(cell) != mesh:
            continue
        if rec["status"] == "skipped":
            arch, shape, _ = cell.split("|")
            lines.append(f"| {arch} | {shape} | — | — | — | *skipped "
                         f"(full attention)* | — | — | — |")
            continue
        if rec["status"] != "ok":
            lines.append(f"| {cell} | FAILED | | | | | | | |")
            continue
        r = rec["roofline"]
        temps = r["bytes_per_device"]["temps"] / 1e9
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']*1e3:.1f} ms "
            f"| {r['memory_s']*1e3:.1f} ms | {r['collective_s']*1e3:.1f} ms "
            f"| **{r['dominant']}** | {temps:.2f} GB "
            f"| {r['useful_ratio']:.2f} | {r['roofline_fraction']:.2%} |")
    return hdr + "\n".join(lines)


def opt_table() -> str:
    path = os.path.join(ROOT, "results", "dryrun_opt.json")
    if not os.path.exists(path):
        return "*optimized sweep pending*"
    with open(path) as f:
        results = json.load(f)
    base = json.load(open(os.path.join(ROOT, "results", "dryrun.json")))
    hdr = ("| arch | shape | bound (base → opt) | dominant | temps/dev "
           "(base → opt) | roofline (base → opt) |\n|---|---|---|---|---|---|\n")
    lines = []
    for cell in sorted(results):
        rec = results[cell]
        if rec["status"] != "ok":
            continue
        r = rec["roofline"]
        b = base.get(cell, {}).get("roofline")
        if not b:
            continue
        bb = max(b["compute_s"], b["memory_s"], b["collective_s"]) * 1e3
        ob = max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e3
        bt = b["bytes_per_device"]["temps"] / 1e9
        ot = r["bytes_per_device"]["temps"] / 1e9
        lines.append(
            f"| {r['arch']} | {r['shape']} | {bb:.0f} → {ob:.0f} ms "
            f"| {r['dominant']} | {bt:.1f} → {ot:.1f} GB "
            f"| {b['roofline_fraction']:.2%} → **{r['roofline_fraction']:.2%}** |")
    return hdr + "\n".join(lines)


def main():
    with open(os.path.join(ROOT, "results", "dryrun.json")) as f:
        results = json.load(f)
    exp_path = os.path.join(ROOT, "EXPERIMENTS.md")
    with open(exp_path) as f:
        text = f.read()

    section = (
        "## 3. §Roofline\n" + HEADER_NOTE
        + "\n### Single-pod (16×16 = 256 chips), paper-faithful baseline\n\n"
        + table(results, "16x16")
        + "\n\n### Multi-pod (2×16×16 = 512 chips), paper-faithful baseline\n\n"
        + table(results, "2x16x16")
        + "\n\n### Optimized configuration (beyond-paper: chunked attention "
          "+ local MoE dispatch), single-pod\n\n"
        + opt_table() + "\n\n")

    text = re.sub(r"## 3\. §Roofline.*?(?=## 4\.)", section, text,
                  flags=re.DOTALL)
    with open(exp_path, "w") as f:
        f.write(text)
    print("rendered §Roofline into EXPERIMENTS.md")


if __name__ == "__main__":
    main()
