#!/usr/bin/env python
"""Diff two BENCH_history.jsonl records — only when they are comparable.

Benchmark numbers recorded in different containers are not comparable:
PR 4's 938 -> 3750 us "regression" was the machine moving, not the
datapath.  Every run stamps `environment.calibration_matmul_us` (a fixed
jit'd-matmul microbenchmark) into its history record, so two records are
comparable exactly when their calibrations agree.  This tool refuses to
diff (exit 2) unless they match within a relative tolerance, then prints a
per-key old/new/ratio table for the numeric results.

Usage:
    python scripts/bench_compare.py                  # last two runs
    python scripts/bench_compare.py -2 -1            # explicit indices
    python scripts/bench_compare.py 0 -1 --prefix stream_routed
    python scripts/bench_compare.py --history BENCH_history.jsonl --tol 0.1

Record selectors index into the history file (negative = from the end,
like Python lists).  Exit codes: 0 = diff printed, 1 = usage/data error,
2 = records not comparable (calibration mismatch).
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

DEFAULT_TOL = 0.25        # relative: |a - b| / min(a, b)


def load_history(path: Path) -> list[dict]:
    if not path.exists():
        sys.exit(f"error: no history file at {path}")
    records = []
    for i, line in enumerate(path.read_text().splitlines()):
        if not line.strip():
            continue
        try:
            records.append(json.loads(line))
        except json.JSONDecodeError as e:
            print(f"warning: skipping malformed record on line {i + 1}: {e}",
                  file=sys.stderr)
    if not records:
        sys.exit(f"error: {path} holds no parseable records")
    return records


def pick(records: list[dict], sel: int, label: str) -> dict:
    try:
        return records[sel]
    except IndexError:
        sys.exit(f"error: {label} selector {sel} out of range "
                 f"({len(records)} records)")


def calibration(rec: dict) -> float | None:
    env = rec.get("environment") or rec.get("_environment") or {}
    val = env.get("calibration_matmul_us")
    return float(val) if val is not None else None


def comparable(old: dict, new: dict, tol: float) -> tuple[bool, str]:
    a, b = calibration(old), calibration(new)
    if a is None or b is None:
        return False, ("one record carries no calibration_matmul_us stamp "
                       "— cannot establish the machines match")
    drift = abs(a - b) / min(a, b)
    msg = (f"calibration_matmul_us: old={a:.0f} new={b:.0f} "
           f"(drift {drift * 100:.1f}%, tolerance {tol * 100:.0f}%)")
    return drift <= tol, msg


def numeric_results(rec: dict) -> dict[str, float]:
    out = {}
    for k, v in (rec.get("results") or {}).items():
        if isinstance(v, bool):
            out[k] = float(v)
        elif isinstance(v, (int, float)):
            out[k] = float(v)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__.splitlines()[0],
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("old", nargs="?", type=int, default=-2,
                    help="history index of the baseline record (default -2)")
    ap.add_argument("new", nargs="?", type=int, default=-1,
                    help="history index of the candidate record (default -1)")
    ap.add_argument("--history", type=Path,
                    default=Path(__file__).resolve().parent.parent
                    / "BENCH_history.jsonl",
                    help="path to BENCH_history.jsonl")
    ap.add_argument("--prefix", default="",
                    help="only diff result keys with this prefix")
    ap.add_argument("--tol", type=float, default=DEFAULT_TOL,
                    help="relative calibration tolerance (default "
                         f"{DEFAULT_TOL})")
    args = ap.parse_args(argv)

    records = load_history(args.history)
    old = pick(records, args.old, "old")
    new = pick(records, args.new, "new")
    print(f"old: [{args.old}] {old.get('utc', '?')}  "
          f"benchmarks={old.get('benchmarks')}")
    print(f"new: [{args.new}] {new.get('utc', '?')}  "
          f"benchmarks={new.get('benchmarks')}")

    ok, msg = comparable(old, new, args.tol)
    print(msg)
    if not ok:
        print("REFUSING to diff: the records were measured on machines "
              "whose calibrations disagree — any delta below would mix "
              "datapath changes with hardware drift.", file=sys.stderr)
        return 2

    a, b = numeric_results(old), numeric_results(new)
    keys = sorted(k for k in (set(a) | set(b))
                  if k.startswith(args.prefix))
    if not keys:
        print(f"no numeric result keys match prefix {args.prefix!r}")
        return 1

    width = max(len(k) for k in keys)
    print(f"\n{'key':<{width}}  {'old':>12}  {'new':>12}  {'ratio':>7}")
    for k in keys:
        ov, nv = a.get(k), b.get(k)
        if nv is None:
            print(f"{k:<{width}}  {ov:12.3f}  {'—':>12}  (old only)")
            continue
        if ov is None:
            print(f"{k:<{width}}  {'—':>12}  {nv:12.3f}  (new only)")
            continue
        ratio = nv / ov if ov else float("inf")
        flag = "" if 0.8 <= ratio <= 1.25 else "  <<"
        print(f"{k:<{width}}  {ov:12.3f}  {nv:12.3f}  {ratio:7.3f}x{flag}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
