#!/usr/bin/env bash
# CI entry point: fast suite first (quick signal), then the full tier-1
# suite — both with the repo's src/ on PYTHONPATH, as documented in README.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "=== fast suite (-m 'not slow') ==="
python -m pytest -q -m "not slow"

echo "=== full tier-1 suite ==="
python -m pytest -x -q
