#!/usr/bin/env bash
# Local mirror of the CI workflow (.github/workflows/ci.yml splits the same
# stages into a fast PR job and a full job + benchmark artifact): fast suite
# first (quick signal), then the full tier-1 suite, then the timed-stream
# benchmark — all with the repo's src/ on PYTHONPATH, as documented in README.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "=== fast suite (-m 'not slow') ==="
python -m pytest -q -m "not slow"

echo "=== full tier-1 suite ==="
python -m pytest -x -q

echo "=== timed-stream benchmark ==="
PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" python benchmarks/run.py --only stream_timed
