#!/usr/bin/env bash
# Local mirror of the CI workflow (.github/workflows/ci.yml splits the same
# stages into a fast PR job and a full job + benchmark artifacts): repo
# hygiene first, then the fast suite (quick signal, includes the fabric
# wrapper-parity battery), then the full tier-1 suite, then the streaming
# benchmarks (the 3-level EXT_4CASE fabric scenario, the timed lane, and the
# degraded-mode variants) — all with the repo's src/ on PYTHONPATH, as
# documented in README.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "=== repo hygiene (no tracked bytecode) ==="
if git ls-files | grep -E '(^|/)__pycache__/|\.pyc$'; then
  echo "ERROR: tracked Python bytecode found (see above); git rm --cached it" >&2
  exit 1
fi

echo "=== fabric static analysis ==="
# Plan + jaxpr + kernel passes over every benchmark scenario (<60 s); the
# optimized-HLO audit (--hlo) stays in the full CI job.
python -m repro.analysis.lint -q

echo "=== degraded-mode battery (health, detours, watchdog recovery) ==="
python -m pytest -q tests/test_degraded.py tests/test_watchdog.py

echo "=== durability battery (crash-consistent checkpoints, kill-resume) ==="
python -m pytest -q tests/test_checkpoint.py

echo "=== fast suite (-m 'not slow') ==="
python -m pytest -q -m "not slow"

echo "=== full tier-1 suite ==="
python -m pytest -x -q

echo "=== fabric static analysis (full: optimized-HLO collective audit) ==="
python -m repro.analysis.lint -q --hlo

echo "=== streaming benchmarks (3-level fabric + timed + degraded + durable + engine) ==="
PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" python benchmarks/run.py --only stream --only stream_timed --only stream_degraded --only stream_ckpt --only stream_routed --only stream_engine

echo "=== benchmark history diff vs previous record (non-blocking) ==="
# Exit 1 = fewer than two records, exit 2 = calibration drift between
# containers (the --tol guard) — both expected on fresh checkouts and
# cross-machine runs, so the step reports but never fails the build.
python scripts/bench_compare.py --prefix stream \
  || echo "bench_compare: skipped (exit $? — no comparable prior record or calibration drift)"
